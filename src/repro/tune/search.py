"""Measured autotuning: time candidate plans, keep the fastest.

Also the home of the repo's **single** warmup/median timing discipline —
``time_fn`` / ``time_pair`` used to live in ``benchmarks/common.py``; the
benchmarks now import them from here so the autotuner and the benchmark
suite cannot drift apart in methodology:

* every timed call is synced with ``jax.block_until_ready``;
* ``warmup`` calls are discarded (jit compile + first-touch);
* the reported number is the **median** over ``iters`` (robust to the
  ±20-30% background jitter of shared containers);
* when the quantity of interest is a *ratio* between two functions, use
  ``time_pair`` — it interleaves the two (A, B, A, B, …) so load drift
  hits both equally.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.obs import calibrate, metrics
from repro.tune import cost

__all__ = ["time_fn", "time_pair", "measure_plan", "autotune"]


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (s) of fn(*args) with device sync."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_pair(fn_a, fn_b, *args, iters: int = 7, warmup: int = 2):
    """Median wall times of two functions measured **interleaved**."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def time_ratio(fn_a, fn_b, *args, iters: int = 8, warmup: int = 1):
    """Robust speed ratio ``t_a / t_b``: **minimum** per series, calls
    interleaved with alternating order.

    ``time_pair``'s independent series medians survive slow drift but not
    burst noise: background spikes on this container last about as long as
    one call, so a median over a handful of samples still swings 30-80%
    even for *identical* functions. Interference only ever ADDS time, so
    the min over an interleaved series is the clean-machine floor of each
    function — measured identical-function min-ratios stay within ~±10%
    where per-iteration medians swung ±35%. Alternating the call order
    cancels cache-warming bias. Returns ``(ratio, min_t_a, min_t_b)``.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    tas, tbs = [], []
    for k in range(iters):
        first, second = (fn_a, fn_b) if k % 2 == 0 else (fn_b, fn_a)
        t0 = time.perf_counter()
        jax.block_until_ready(first(*args))
        t1 = time.perf_counter()
        jax.block_until_ready(second(*args))
        t2 = time.perf_counter()
        ta, tb = (t1 - t0, t2 - t1) if k % 2 == 0 else (t2 - t1, t1 - t0)
        tas.append(ta)
        tbs.append(tb)
    ta, tb = min(tas), min(tbs)
    return ta / tb, ta, tb


# ---------------------------------------------------------------------------
# plan measurement
# ---------------------------------------------------------------------------


def _operands(plan: cost.Plan, seed: int = 0):
    """Deterministic random operands matching the plan's problem key."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    lead = (plan.batch,) if plan.batch else ()
    dt = jnp.dtype(plan.dtype)
    a = jnp.asarray(rng.standard_normal((*lead, plan.m, plan.n)), dt)
    if plan.op == "gemm_tn":
        b = jnp.asarray(rng.standard_normal((*lead, plan.m, plan.k)), dt)
        return (a, b)
    if plan.op == "solve":
        # k is the RHS count; lstsq is unbatched (2-D design matrix)
        b = jnp.asarray(rng.standard_normal((plan.m, plan.k)), dt)
        return (a, b)
    return (a,)


def measure_plan(
    plan: cost.Plan, *, iters: int = 3, warmup: int = 1, seed: int = 0
) -> float:
    """Median seconds of one plan's jitted callable on synthetic operands."""
    from repro.tune.apply import build_callable

    fn = build_callable(plan)
    args = _operands(plan, seed)
    return time_fn(fn, *args, iters=iters, warmup=warmup)


def autotune(
    op: str,
    m: int,
    n: int,
    k: Optional[int] = None,
    *,
    batch: int = 0,
    dtype: str = "float32",
    out: str = "dense",
    backend: str = "cpu",
    devices: int = 1,
    row_devices: int = 1,
    max_candidates: int = 4,
    iters: int = 8,
    warmup: int = 1,
    margin: float = 0.15,
) -> cost.Plan:
    """Measured sweep: every analytic top-``max_candidates`` candidate is
    timed **paired against the hardcoded default** (``time_ratio`` —
    per-iteration ratios with alternating order survive both load drift
    and burst noise), and a candidate replaces the default only when it
    wins by more than ``margin``.

    The default plan is the reference of every comparison, so the tuned
    plan can never be slower than the hardcoded baseline by more than
    measurement noise — and within-noise "wins" (which a later re-measure
    would flip) keep the default outright.
    """
    from repro.tune.apply import build_callable

    key = dict(batch=batch, dtype=dtype, out=out, backend=backend,
               devices=devices, row_devices=row_devices)
    base = cost.default_plan(op, m, n, k, **key)
    cands = [
        c for c in cost.candidates(op, m, n, k, **key)[:max_candidates]
        if not _same_dispatch(c, base)
    ]

    metrics.inc("tune.autotune.runs")
    base_fn = build_callable(base)
    args = _operands(base)
    t_base = time_fn(base_fn, *args, iters=iters, warmup=warmup)
    calibrate.record(base, t_base, source="autotune")
    best = (1.0, base, t_base, t_base)
    for cand in cands:
        metrics.inc("tune.autotune.trials")
        cand_fn = build_callable(cand)
        ratio, tb, tc = time_ratio(
            base_fn, cand_fn, *args, iters=iters, warmup=warmup
        )
        if ratio > 1.0 + margin:
            # a promising win must REPLICATE in a second, independent
            # measurement window (sustained load bursts can corrupt one
            # whole window against a single function); keep the
            # conservative minimum of the two windows.
            r2, tb2, tc2 = time_ratio(base_fn, cand_fn, *args, iters=iters, warmup=0)
            ratio = min(ratio, r2)
            tb, tc = min(tb, tb2), min(tc, tc2)
        # every trial's clean-machine floor is a calibration pair for the
        # candidate's analytic prediction (candidates() stamps predicted_s)
        calibrate.record(cand, tc, source="autotune")
        # ratio > 1: candidate beats the default, burst-noise-robustly
        if ratio > 1.0 + margin and ratio > best[0]:
            best = (ratio, cand, tc, tb)
    ratio_won, plan, t, t_baseline = best
    if plan is base:
        metrics.inc("tune.autotune.kept_default")
    else:
        metrics.inc("tune.autotune.wins")
        metrics.observe("tune.autotune.win_margin", ratio_won - 1.0)
    return dataclasses.replace(
        plan, source="measured", measured_s=t, baseline_s=t_baseline
    )


def _same_dispatch(a: cost.Plan, b: cost.Plan) -> bool:
    """True when two plans dispatch identically (tunables equal)."""
    keys = ("algorithm", "n_base", "packed_block", "use_kernels",
            "syrk_blocks", "gemm_blocks", "leaf_dispatch", "method",
            "nb", "tile_w", "comm_schedule", "row_devices")
    return all(getattr(a, f) == getattr(b, f) for f in keys)
