"""``repro.solve`` — packed-native normal-equations solvers on the ATA stack.

The paper's opening claim is that ``AᵀA`` "appears as an intermediate
operation in the solution of a wide set of problems"; this package is the
layer that closes that loop. Everything downstream of a planned gram
product stays in the packed lower-triangular block form — factor, solve,
and precondition directly on :class:`repro.core.SymmetricMatrix` without
ever materializing the ``O(n²)`` dense mirror:

* :mod:`repro.solve.cholesky`   — blocked right-looking Cholesky walking
  the packed block pytree in place (Pallas ``potrf``/``trsm`` base kernels
  on TPU, batched per the ``repro.kernels`` contract);
* :mod:`repro.solve.triangular` — blocked forward/backward substitution
  against the packed factor, multi-RHS;
* :mod:`repro.solve.lstsq`      — the front door: ``lstsq(A, b, ridge=…)``
  = planned ``ata`` → packed Cholesky → two triangular solves, dispatched
  through ``repro.tune.plan(op="solve")`` (which may instead choose CG);
* :mod:`repro.solve.cg`         — matrix-free conjugate gradient on the
  gram *operator* (each iteration one planned TN product pair — ``AᵀA``
  is never formed) for the tall-skinny / many-RHS-free regime.

Layering: ``solve`` sits ABOVE ``core`` and ``tune`` (algorithms →
planner → kernels → **solvers**) — it consumes plans and packed storage,
and only the dedicated base kernels reach below.
"""

from repro.solve.cholesky import CholeskyFactor, cholesky
from repro.solve.cg import cg_gram, cg_lstsq
from repro.solve.lstsq import lstsq
from repro.solve.triangular import solve_cholesky, solve_triangular

__all__ = [
    "cholesky",
    "CholeskyFactor",
    "solve_triangular",
    "solve_cholesky",
    "lstsq",
    "cg_gram",
    "cg_lstsq",
]
