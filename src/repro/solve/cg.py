"""Matrix-free conjugate gradient on the gram *operator*.

For the tall-skinny / ill-conditioned-budget regime the planner can decide
that factoring the gram is not worth it: ``cg_lstsq`` solves the ridge
normal equations

    (AᵀA + λI)·x = Aᵀb

without ever *forming* ``AᵀA`` — each CG iteration applies the operator as
one planned TN product pair,

    p ↦ Aᵀ(A·p) + λp        (``A·p`` a plain dot, ``Aᵀ(·)`` the planned
                             FastStrassen TN product — ``Aᵀ`` is never
                             materialized, per the paper's Section 3),

so the resident footprint is ``O(m·r + n·r)`` instead of the ``O(n²)``
gram. Multi-RHS: the textbook iteration runs vectorized over the ``r``
columns with per-column step sizes; converged columns freeze (their
updates are masked to zero), so one fixed-trip ``fori_loop`` serves every
column — jit-stable, no host sync.

``cg_gram`` is the generic SPD-operator CG the lstsq wrapper builds on.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import obs

__all__ = ["cg_gram", "cg_lstsq"]


def cg_gram(
    matvec: Callable,
    b: jax.Array,
    *,
    iters: int,
    tol: float = 1e-6,
    x0: Optional[jax.Array] = None,
) -> jax.Array:
    """CG for ``G·x = b`` with SPD operator ``matvec: (n, r) → (n, r)``.

    ``b``: ``(n,)`` or ``(n, r)``; columns iterate independently (separate
    α/β per column) inside one vectorized loop. Stops *updating* a column
    once its residual norm falls below ``tol·‖b‖`` — the loop itself is a
    fixed-trip ``fori_loop`` so the schedule is static under jit.
    """
    vector = b.ndim == 1
    if vector:
        b = b[:, None]
    b = b.astype(jnp.float32)
    x = jnp.zeros_like(b) if x0 is None else x0.astype(jnp.float32)
    r0 = b - matvec(x) if x0 is not None else b
    stop2 = (tol * tol) * jnp.maximum(jnp.sum(b * b, axis=0), 1e-30)

    def body(_, carry):
        x, r, p, rs = carry
        live = rs > stop2                           # per-column progress mask
        gp = matvec(p)
        denom = jnp.sum(p * gp, axis=0)
        alpha = jnp.where(live, rs / jnp.maximum(denom, 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * gp
        rs_new = jnp.sum(r * r, axis=0)
        beta = jnp.where(live, rs_new / jnp.maximum(rs, 1e-30), 0.0)
        p = r + beta * p
        return x, r, p, rs_new

    rs = jnp.sum(r0 * r0, axis=0)
    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x, r0, r0, rs))
    return x[:, 0] if vector else x


def cg_lstsq(
    a: jax.Array,
    b: jax.Array,
    *,
    ridge: float = 0.0,
    iters: Optional[int] = None,
    tol: Optional[float] = None,
    plan=None,
    gemm_plan=None,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
) -> jax.Array:
    """Ridge least squares via CG on the normal-equations operator.

    ``a``: ``(m, n)``; ``b``: ``(m,)`` or ``(m, r)``. Each iteration is one
    planned TN product pair — the dispatch of the ``Aᵀ(·)`` product comes,
    in order, from ``gemm_plan``, explicit ``n_base``/``variant`` pins
    (bitwise-reproducible static dispatch — what ``lstsq(method='cg')``
    passes), the solve ``plan``'s algorithm tunables, or the front door.
    Iteration budget and tolerance default to ``repro.tune.defaults``
    (``CG_MAX_ITERS`` capped by ``n`` — exact termination in exact
    arithmetic — and ``CG_TOL``).
    """
    from repro.core.strassen import strassen_tn
    from repro.tune import defaults

    if a.ndim != 2:
        raise ValueError(f"cg_lstsq expects a 2-D operand, got {a.shape}")
    m, n = a.shape
    if iters is None:
        iters = min(n, defaults.CG_MAX_ITERS)
    if tol is None:
        tol = defaults.CG_TOL
    a = a.astype(jnp.float32)
    vector = b.ndim == 1
    b2 = (b[:, None] if vector else b).astype(jnp.float32)

    kw = {}
    if gemm_plan is not None:
        kw["plan"] = gemm_plan
    elif n_base is not None or variant is not None:
        kw["n_base"] = n_base
        kw["variant"] = variant
    elif plan is not None and getattr(plan, "algorithm", None) is not None:
        # inherit the solve plan's algorithm tunables for the TN products
        # ('dense' expresses itself as a cutoff covering the whole operand,
        # same as resolve_tunables does for product plans)
        kw["n_base"] = (
            max(plan.n_base, m, n) if plan.algorithm == "dense" else plan.n_base
        )
        kw["variant"] = plan.variant

    obs.metrics.inc("solve.cg.calls")
    # the fixed trip count IS the iteration budget (columns converge by
    # freezing inside the loop, not by exiting it)
    obs.metrics.set_gauge("solve.cg.iters", iters)

    def matvec(p):
        # (m, r) plain NN dot — accumulation width pinned so the operator
        # keeps f32 accumulation even if the cast above is ever relaxed to
        # sub-f32 operands (the repro.check acc-dtype contract)
        ap = jnp.matmul(a, p, preferred_element_type=jnp.float32)
        atap = strassen_tn(a, ap, **kw)    # Aᵀ(A·p): planned TN product
        return atap + ridge * p if ridge else atap

    with obs.span("solve.cg", iters=iters, m=m, n=n):
        rhs = strassen_tn(a, b2, **kw)     # Aᵀb — same planned TN dispatch
        x = cg_gram(matvec, rhs, iters=iters, tol=tol)
    return x[:, 0] if vector else x
