"""Blocked right-looking Cholesky on the packed lower-triangular block grid.

``cholesky`` factors an SPD :class:`repro.core.SymmetricMatrix` (or a dense
square, which is packed first by a pure gather) into a
:class:`CholeskyFactor` holding the *same* ``(..., T, bn, bn)`` packed
block pytree — the factorization walks the block grid in place and never
materializes a dense ``(n, n)`` anywhere:

    for block column j:                            (right-looking)
        S_jj   = A[j,j] − Σ_{k<j} L[j,k]·L[j,k]ᵀ   (one NT block einsum)
        L[j,j] = potrf(S_jj)                        (diagonal base kernel)
        S_ij   = A[i,j] − Σ_{k<j} L[i,k]·L[j,k]ᵀ   (one batched einsum)
        L[i,j] = trsm(L[j,j], S_ij)  for all i > j  (ONE batched panel
                                                     launch per column)

Base engines follow the plan like every other consumer of the stack:
``plan.use_kernels`` → the Pallas ``potrf``/``trsm`` kernels
(``repro.kernels``), whose leading batch grid dimension receives the whole
flattened ``batch × panel-rows`` stack per the PR-4 batched-dispatch
contract — a batched Shampoo stat stack factors as ONE launch per block
column per op. Otherwise the jnp/LAPACK-lowered base
(``jnp.linalg.cholesky`` / ``lax.linalg.triangular_solve``) serves every
backend. Either way the *walk* — and therefore the block arithmetic and
its float rounding — is identical, which is what makes packed and dense
inputs factor bitwise-identically (tested).

Padding: the packed grid covers ``nb·bn ≥ n``; the pad rows/cols of a gram
are zero, which would make the trailing diagonal block singular. The walk
masks the tail block's pad region to the identity before its ``potrf``, so
the factor is identity there and zero-padded right-hand sides solve to
zero-padded solutions — the crop at the end is exact.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.symmetric import (
    SymmetricMatrix,
    default_block_size,
    diag_block_indices,
    sym_tile,
    tri_block_indices,
)

__all__ = ["CholeskyFactor", "cholesky"]


@jax.tree_util.register_pytree_node_class
class CholeskyFactor:
    """Lower-triangular Cholesky factor in packed block storage.

    Same geometry as :class:`SymmetricMatrix` — ``blocks: (..., T, bn, bn)``
    under the row-major lower enumeration ``t = i(i+1)/2 + j`` — but the
    content contract differs: diagonal tiles are **lower-triangular**
    (strict upper half zero) and there is no mirror anywhere; ``to_dense``
    assembles the lower-triangular ``L`` with zeros above the diagonal.
    Registered as a pytree, so factors ride through ``jit``/``lax.cond``
    and live directly in optimizer state (the packed-Shampoo p=2 path) and
    checkpoints (blocks + ``(n, bn)`` metadata — see DESIGN.md §5).
    """

    __slots__ = ("blocks", "n", "bn")

    def __init__(self, blocks, n: int, bn: int):
        self.blocks = blocks
        self.n = int(n)
        self.bn = int(bn)

    @property
    def nb(self) -> int:
        return -(-self.n // self.bn)

    @property
    def t_total(self) -> int:
        return self.nb * (self.nb + 1) // 2

    @property
    def shape(self):
        return tuple(self.blocks.shape[:-3]) + (self.n, self.n)

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def nbytes(self) -> int:
        return int(self.blocks.size) * self.blocks.dtype.itemsize

    def tree_flatten(self):
        return (self.blocks,), (self.n, self.bn)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    @classmethod
    def identity(cls, n: int, bn: int, batch=(), dtype=jnp.float32):
        """The identity factor (L = I): the well-posed init value for
        factor-shaped optimizer state slots."""
        bn = default_block_size(n, bn)
        nb = -(-n // bn)
        t = nb * (nb + 1) // 2
        base = np.zeros((t, bn, bn), np.float32)
        base[diag_block_indices(nb)] = np.eye(bn, dtype=np.float32)
        blocks = jnp.broadcast_to(
            jnp.asarray(base, dtype), (*batch, t, bn, bn)
        )
        return cls(blocks, n, bn)

    def block(self, i: int, j: int):
        """The ``(..., bn, bn)`` factor tile at block position ``(i, j)``."""
        if j > i:
            raise ValueError(f"block ({i}, {j}) lies in the upper triangle")
        return self.blocks[..., i * (i + 1) // 2 + j, :, :]

    def to_dense(self):
        """Dense lower-triangular ``(..., n, n)`` L — conversion boundary
        only (tests/interop); the solvers never call this."""
        nb, bn, n = self.nb, self.bn, self.n
        i_idx, j_idx = tri_block_indices(nb)

        def unpack2d(blocks):
            z = jnp.zeros((nb, bn, nb, bn), blocks.dtype)
            z = z.at[i_idx, :, j_idx, :].set(blocks)
            return z.reshape(nb * bn, nb * bn)[:n, :n]

        fn = unpack2d
        for _ in self.blocks.shape[:-3]:
            fn = jax.vmap(fn)
        return fn(self.blocks)

    def __repr__(self):
        return (
            f"CholeskyFactor(n={self.n}, bn={self.bn}, "
            f"blocks={getattr(self.blocks, 'shape', None)}, "
            f"dtype={getattr(self.blocks, 'dtype', None)})"
        )


# ---------------------------------------------------------------------------
# base engines (the solver analogue of core.strassen._plan_base_fns)
# ---------------------------------------------------------------------------


def _flat_call(fn: Callable, *ops):
    """Call a base kernel on operands with arbitrary leading dims, flattened
    to the ONE leading batch dim of the ``repro.kernels`` batched-grid
    contract (2-D operands pass through unflattened)."""
    lead = ops[0].shape[:-2]
    if not lead:
        return fn(*ops)
    flat = [o.reshape(-1, *o.shape[-2:]) for o in ops]
    out = fn(*flat)
    return out.reshape(*lead, *out.shape[-2:])


def _potrf_jnp(s):
    return jnp.linalg.cholesky(s)


def _trsm_panel_jnp(l, p):
    # X·Lᵀ = P  (the factorization panel op), batched over leading dims
    return jax.lax.linalg.triangular_solve(
        l, p, left_side=False, lower=True, transpose_a=True
    )


def base_solver_fns(plan):
    """(base_potrf, base_trsm) for the factor walk under this plan.

    ``use_kernels=True`` → the Pallas kernels (compiled on TPU, interpret
    elsewhere — ``kernels.ops`` decides); otherwise the jnp bases. Both
    accept one flattened leading batch dim (``_flat_call`` guarantees it).
    """
    if plan is not None and getattr(plan, "use_kernels", False):
        from repro.kernels import ops

        return ops.potrf, functools.partial(ops.trsm, transpose=True)
    return _potrf_jnp, _trsm_panel_jnp


# ---------------------------------------------------------------------------
# the factor walk
# ---------------------------------------------------------------------------


def _pad_identity_mask(n: int, nb: int, bn: int):
    """(valid_2d, eye_pad) masks for the trailing diagonal block: zero the
    pad rows/cols, then place ones on the pad diagonal — the tail block
    factors as identity and zero-padded RHS stay zero."""
    d = n - (nb - 1) * bn  # valid extent of the last block, 1..bn
    valid = np.zeros((bn, bn), np.float32)
    valid[:d, :d] = 1.0
    eye_pad = np.zeros((bn, bn), np.float32)
    eye_pad[range(d, bn), range(d, bn)] = 1.0
    return jnp.asarray(valid), jnp.asarray(eye_pad)


def cholesky(
    a: Union[SymmetricMatrix, jax.Array],
    *,
    ridge: float = 0.0,
    plan=None,
    packed_block: Optional[int] = None,
    base_potrf: Optional[Callable] = None,
    base_trsm: Optional[Callable] = None,
) -> CholeskyFactor:
    """Packed blocked Cholesky: ``A = L·Lᵀ`` on the block grid, in place.

    Args:
      a: SPD :class:`SymmetricMatrix` (any leading batch dims on its
        blocks), or a dense ``(..., n, n)`` square — packed first via the
        pure-gather :meth:`SymmetricMatrix.from_dense`, after which the
        *identical* walk runs, so packed and dense inputs of equal values
        factor bitwise-identically.
      ridge: optional ``+ ridge·I`` on the logical diagonal before
        factoring (packed-native — only diagonal tiles touched).
      plan: a :class:`repro.tune.Plan` — supplies the packed block size
        (dense inputs) and the base-engine choice (``use_kernels``).
      packed_block: block size override when packing a dense input.
      base_potrf / base_trsm: explicit base engines (must accept one
        leading batch dim, per the ``repro.kernels`` contract).

    Returns:
      :class:`CholeskyFactor` with the same batch dims and block grid.
    """
    if not isinstance(a, SymmetricMatrix):
        if packed_block is None:
            packed_block = (
                plan.packed_block if plan is not None else None
            )
        if packed_block is None:
            from repro.tune.defaults import DEFAULT_PACKED_BLOCK

            packed_block = DEFAULT_PACKED_BLOCK
        a = SymmetricMatrix.from_dense(a, packed_block)
    if ridge:
        a = a.add_scaled_identity(ridge)
    if base_potrf is None and base_trsm is None:
        base_potrf, base_trsm = base_solver_fns(plan)
    elif base_potrf is None or base_trsm is None:
        raise ValueError("pass both base_potrf and base_trsm, or neither")

    nb, bn, n = a.nb, a.bn, a.n
    pad = nb * bn - n
    i_idx, j_idx = tri_block_indices(nb)
    tiles = {
        (int(i_idx[t]), int(j_idx[t])): a.block(int(i_idx[t]), int(j_idx[t]))
        for t in range(a.t_total)
    }

    out = {}
    for j in range(nb):
        s = tiles[(j, j)]
        if j:
            lrow = jnp.stack([out[(j, k)] for k in range(j)], axis=0)
            # pin the Schur accumulation width: einsum would otherwise
            # inherit the operand dtype (sub-f32 for a bf16 factor) —
            # the repro.check acc-dtype contract
            s = s - jnp.einsum(
                "k...ab,k...cb->...ac", lrow, lrow,
                preferred_element_type=jnp.float32,
            )
        # the LOWER half of a packed diagonal tile is the authoritative
        # content (straddling producers may leave intra-tile upper corners
        # unwritten — to_dense's mirror reconstructs them); mirror it here
        # so every base engine (jnp.linalg.cholesky symmetrizes its input!)
        # sees the same full SPD tile.
        s = sym_tile(s)
        if pad and j == nb - 1:
            valid, eye_pad = _pad_identity_mask(n, nb, bn)
            s = s * valid + eye_pad
        out[(j, j)] = _flat_call(base_potrf, s)

        rows = range(j + 1, nb)
        if not rows:
            continue
        # the sub-diagonal panel of column j, leading-axis-major for the
        # batched-kernel contract (col_panel enumerates ascending i)
        p = jnp.moveaxis(a.col_panel(j), -3, 0)
        if j:
            li = jnp.stack(
                [jnp.stack([out[(i, k)] for k in range(j)], 0) for i in rows], 0
            )
            p = p - jnp.einsum(
                "rk...ab,k...cb->r...ac", li, lrow,
                preferred_element_type=jnp.float32,
            )
        ljj = jnp.broadcast_to(out[(j, j)], p.shape)
        panel = _flat_call(base_trsm, ljj, p)
        for r, i in enumerate(rows):
            out[(i, j)] = panel[r]

    blocks = jnp.stack(
        [out[(int(i_idx[t]), int(j_idx[t]))] for t in range(a.t_total)],
        axis=-3,
    )
    return CholeskyFactor(blocks, n, bn)
