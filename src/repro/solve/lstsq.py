"""``solve.lstsq`` — the front door of the packed solver layer.

One call closes the paper's loop end-to-end:

    x = solve.lstsq(A, b, ridge=…)

dispatched through ``repro.tune.plan(op="solve", m, n, k=r)``. The planner
prices the two methods with the exact counters of ``core.reference``
(potrf/trsm flops joined with the packed write-traffic model) and picks
per shape and RHS count:

* ``method='factor'`` — planned ``ata(out='packed')`` → packed blocked
  Cholesky → two packed triangular substitutions. **No dense ``(n, n)``
  exists anywhere in the jaxpr** (regression-tested): the gram arrives as
  the packed block pytree, the factor overwrites the same geometry, and
  the substitutions walk blocks.
* ``method='cg'`` — matrix-free CG on the gram operator (one planned TN
  product pair per iteration; the gram is never *formed* at all) for the
  regime where ``iters·4mnr`` undercuts ``mn² + n³/3``.

Pinning ``method=`` (or passing a frozen ``plan``) bypasses the planner,
with the same reproducibility contract as every other consumer of the
stack.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.solve.cg import cg_lstsq
from repro.solve.cholesky import cholesky
from repro.solve.triangular import solve_cholesky

__all__ = ["lstsq"]


def lstsq(
    a: jax.Array,
    b: jax.Array,
    *,
    ridge: float = 0.0,
    plan=None,
    method: Optional[str] = None,
    packed_block: Optional[int] = None,
    iters: Optional[int] = None,
    tol: Optional[float] = None,
) -> jax.Array:
    """Least squares ``min_x ‖A·x − b‖² + ridge·‖x‖²`` via the normal
    equations, packed-native.

    Args:
      a: ``(m, n)`` design matrix (any rectangular shape).
      b: ``(m,)`` or ``(m, r)`` right-hand side(s).
      ridge: Tikhonov term ``λ`` — added on the gram's logical diagonal
        (packed-native) before factoring, or inside the CG operator.
      plan: frozen :class:`repro.tune.Plan` with ``op='solve'`` carrying
        every tunable (method, gram algorithm/cutoff, packed block, base
        kernels). With no plan and no pinned ``method`` the dispatch is
        planned through ``repro.tune.plan`` — analytic model or cache.
      method: ``'factor'`` or ``'cg'`` — pinning it manually bypasses the
        planner (static defaults fill the rest, bitwise-reproducible).
      packed_block: packed grid block-size override (factor path).
      iters, tol: CG budget overrides (CG path).

    Returns:
      ``x``: ``(n,)`` or ``(n, r)``, matching ``b``.
    """
    if a.ndim != 2:
        raise ValueError(f"lstsq expects a 2-D design matrix, got {a.shape}")
    m, n = a.shape
    r = 1 if b.ndim == 1 else b.shape[-1]
    if b.shape[0] != m:
        raise ValueError(f"rhs rows {b.shape[0]} != design rows {m}")

    if plan is None and method is None:
        from repro import tune

        plan = tune.plan(
            op="solve", m=m, n=n, k=r, dtype=str(jnp.dtype(a.dtype)),
            out="packed",
        )
    if method is None:
        method = getattr(plan, "method", None) or "factor"
    if method not in ("factor", "cg"):
        raise ValueError(f"unknown solve method {method!r}; use 'factor' or 'cg'")
    # a pinned method with no plan bypasses the planner entirely — the
    # inner products run on the static defaults, so explicit calls stay
    # bitwise reproducible regardless of cache state (the same contract as
    # pinning n_base on ata; resolve_tunables' third regime).
    pinned = plan is None
    if pinned:
        from repro.tune import defaults as _defaults

        static_kw = dict(
            n_base=_defaults.DEFAULT_N_BASE, variant=_defaults.DEFAULT_VARIANT
        )

    obs.metrics.inc(f"dispatch.solve.{method}")
    t0 = obs.dispatch_start(plan, a)
    if method == "cg":
        with obs.span("solve.lstsq", method="cg", m=m, n=n, r=r):
            if pinned:
                x = cg_lstsq(a, b, ridge=ridge, iters=iters, tol=tol,
                             **static_kw)
            else:
                x = cg_lstsq(a, b, ridge=ridge, iters=iters, tol=tol, plan=plan)
            return obs.dispatch_finish(plan, t0, x)

    # --- factor path: planned packed gram → packed Cholesky → substitutions
    from repro.core.ata import ata
    from repro.core.strassen import _dot_tn

    ata_plan = None
    ata_kw = {}
    if plan is not None:
        if packed_block is None:
            packed_block = plan.packed_block
        # predicted_s=None: the solve-level prediction prices the whole
        # pipeline, not the inner gram — carrying it over would record a
        # mislabeled op='ata' calibration row at the inner dispatch.
        ata_plan = dataclasses.replace(
            plan, op="ata", k=n, out="packed", method=None, predicted_s=None
        )
    else:
        ata_kw = static_kw
    with obs.span("solve.lstsq", method="factor", m=m, n=n, r=r):
        a32 = a.astype(jnp.float32)
        with obs.span("solve.gram"):
            gram = ata(a32, plan=ata_plan, out="packed",
                       packed_block=packed_block, **ata_kw)
        if ridge:
            gram = gram.add_scaled_identity(ridge)
        vector = b.ndim == 1
        b2 = (b[:, None] if vector else b).astype(jnp.float32)
        rhs = _dot_tn(a32, b2, jnp.float32)          # Aᵀb, Aᵀ never formed
        with obs.span("solve.cholesky"):
            factor = cholesky(gram, plan=plan)
        with obs.span("solve.substitution"):
            x = solve_cholesky(factor, rhs, plan=plan)
        x = x[..., 0] if vector else x
        return obs.dispatch_finish(plan, t0, x)
