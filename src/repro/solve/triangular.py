"""Blocked forward/backward substitution against a packed Cholesky factor.

``solve_triangular`` runs the block recurrence on the packed factor grid —
multi-RHS, batched, and with no dense ``(n, n)`` anywhere:

    forward  (L·y = b):     y_i = L[i,i]⁻¹·(b_i − Σ_{j<i} L[i,j]·y_j)
    backward (Lᵀ·x = y):    x_i = L[i,i]⁻ᵀ·(y_i − Σ_{j>i} L[j,i]ᵀ·x_j)

The Σ terms are one batched NT/TN block einsum per step (tile-level ops —
``L[j,i]ᵀ`` transposes a ``bn×bn`` tile, never a matrix); the diagonal
solves go to the plan's base engine: the Pallas ``trsm`` kernel
(``X·Lᵀ = B`` / ``X·L = B`` on the transposed RHS tile) when
``plan.use_kernels``, else ``lax.linalg.triangular_solve``.

``solve_cholesky`` composes the two substitutions into a full
``A·x = b`` solve given ``A = L·Lᵀ``.

Right-hand sides: ``(..., n)`` or ``(..., n, r)`` with leading dims
matching the factor's batch dims (or none). Rows beyond ``n`` are
zero-padded onto the block grid; the factor's identity pad (see
``repro.solve.cholesky``) maps them back to zero, so the final crop is
exact.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.solve.cholesky import CholeskyFactor, _flat_call

__all__ = ["solve_triangular", "solve_cholesky"]


def _left_solve_jnp(l, c, *, transpose: bool):
    return jax.lax.linalg.triangular_solve(
        l, c, left_side=True, lower=True, transpose_a=transpose
    )


def _left_solve_kernel(l, c, *, transpose: bool):
    # L·y = c  ⇔  yᵀ·Lᵀ = cᵀ   (kernel transpose=True)
    # Lᵀ·y = c ⇔  yᵀ·L  = cᵀ   (kernel transpose=False)
    from repro.kernels import ops

    ct = jnp.swapaxes(c, -1, -2)
    yt = _flat_call(
        lambda lf, cf: ops.trsm(lf, cf, transpose=not transpose), l, ct
    )
    return jnp.swapaxes(yt, -1, -2)


def _diag_solver(plan, base_trsm: Optional[Callable]):
    if base_trsm is not None:
        return base_trsm
    if plan is not None and getattr(plan, "use_kernels", False):
        return _left_solve_kernel
    return _left_solve_jnp


def solve_triangular(
    f: CholeskyFactor,
    b: jax.Array,
    *,
    transpose: bool = False,
    plan=None,
    base_trsm: Optional[Callable] = None,
) -> jax.Array:
    """Solve ``L·y = b`` (``transpose=False``) or ``Lᵀ·x = b`` against the
    packed factor, blockwise. ``b``: ``(..., n)`` or ``(..., n, r)``;
    returns the matching shape. ``base_trsm(l, c, transpose=...)`` must
    solve the *left* diagonal-tile system on ``(..., bn, r)`` tiles.
    """
    nb, bn, n = f.nb, f.bn, f.n
    vector = b.ndim == f.blocks.ndim - 2  # (..., n) vs (..., n, r)
    if vector:
        b = b[..., None]
    if b.shape[-2] != n:
        raise ValueError(f"rhs rows {b.shape[-2]} != factor n {n}")
    pad = nb * bn - n
    if pad:
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 2) + [(0, pad), (0, 0)])
    batch = b.shape[:-2]
    r = b.shape[-1]
    bs = b.reshape(*batch, nb, bn, r)
    solve_diag = _diag_solver(plan, base_trsm)

    xs: dict = {}
    order = range(nb) if not transpose else range(nb - 1, -1, -1)
    for i in order:
        c = bs[..., i, :, :]
        if not transpose:
            done = range(i)  # subtract L[i,j]·y_j, j < i
            if done:
                lt = jnp.stack([f.block(i, j) for j in done], axis=0)
                xt = jnp.stack([xs[j] for j in done], axis=0)
                # f32 accumulation regardless of operand dtype (the
                # repro.check acc-dtype contract)
                c = c - jnp.einsum(
                    "k...ab,k...br->...ar", lt, xt,
                    preferred_element_type=jnp.float32,
                )
        else:
            done = range(i + 1, nb)  # subtract L[j,i]ᵀ·x_j, j > i
            if done:
                lt = jnp.stack([f.block(j, i) for j in done], axis=0)
                xt = jnp.stack([xs[j] for j in done], axis=0)
                c = c - jnp.einsum(
                    "k...ba,k...br->...ar", lt, xt,
                    preferred_element_type=jnp.float32,
                )
        xs[i] = solve_diag(f.block(i, i), c, transpose=transpose)

    x = jnp.concatenate([xs[i] for i in range(nb)], axis=-2)[..., :n, :]
    return x[..., 0] if vector else x


def solve_cholesky(
    f: CholeskyFactor,
    b: jax.Array,
    *,
    plan=None,
    base_trsm: Optional[Callable] = None,
) -> jax.Array:
    """Full SPD solve ``A·x = b`` given the packed factor ``A = L·Lᵀ``:
    forward then backward substitution, packed end-to-end."""
    y = solve_triangular(f, b, transpose=False, plan=plan, base_trsm=base_trsm)
    return solve_triangular(f, y, transpose=True, plan=plan, base_trsm=base_trsm)
