"""Optimizers: AdamW baseline, ATA-powered distributed Shampoo, PowerSGD
gradient compression, LR schedules."""

from repro.optim.adamw import Optimizer, adamw, apply_updates, clip_by_global_norm, global_norm
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.shampoo import inverse_pth_root, shampoo

__all__ = [
    "Optimizer",
    "adamw",
    "shampoo",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "constant",
    "warmup_cosine",
    "inverse_pth_root",
    "build",
]


def build(opt_cfg, total_steps: int = 10_000):
    """Build an optimizer from an OptimizerConfig."""
    sched = warmup_cosine(opt_cfg.lr, opt_cfg.warmup_steps, total_steps)
    if opt_cfg.name == "adamw":
        return adamw(
            sched, opt_cfg.beta1, opt_cfg.beta2, opt_cfg.eps, opt_cfg.weight_decay
        )
    if opt_cfg.name == "shampoo":
        return shampoo(
            sched,
            block=opt_cfg.shampoo_block,
            beta1=opt_cfg.beta1,
            beta2=opt_cfg.beta2,
            eps=opt_cfg.eps,
            weight_decay=opt_cfg.weight_decay,
            update_every=opt_cfg.shampoo_update_every,
            n_base=opt_cfg.shampoo_n_base,
        )
    raise ValueError(f"unknown optimizer {opt_cfg.name!r}")
