"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def constant(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)

    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
