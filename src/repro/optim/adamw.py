"""AdamW — the baseline optimizer (optax-style functional interface).

``Optimizer`` is a (init, update) pair over param pytrees:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

The step counter lives in the state; the learning rate is a schedule
function of it.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "apply_updates", "global_norm", "clip_by_global_norm"]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw(
    lr_schedule: Callable,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_schedule(step)
        bc1 = 1.0 - beta1 ** step.astype(jnp.float32)
        bc2 = 1.0 - beta2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = beta1 * m + (1 - beta1) * g
            v = beta2 * v + (1 - beta2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)
