"""PowerSGD-style low-rank gradient compression, built on the paper's ops.

For DP gradient reduction at scale, rank-r compression replaces the dense
all-reduce of a (m, n) gradient with all-reduces of (m, r) and (n, r)
factors (r ≪ min(m, n)). The hot linear algebra is the paper's:

  * ``Q ← GᵀP``  — a TN product → :func:`repro.core.strassen_tn`;
  * orthonormalization gram ``PᵀP`` — :func:`repro.core.ata` (+ Cholesky
    whitening, cheaper and TPU-friendlier than per-column Gram-Schmidt).

Error feedback keeps the compression unbiased over time: the residual
``G − P·Qᵀ`` is added back into the next step's gradient.

Usage: wrap the per-device (pre-all-reduce) gradients; the returned factors
are what the DP collective reduces. :func:`compress_sharded` is the
shard_map-native variant for **row-sharded** gradients: the factor psums
stay, and the orthonormalization gram crosses the mesh **in packed form**
(``gram_rowshard(..., out='packed')`` — the paper's low(C) retrieval saving
applied to the optimizer's collective bytes).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ata import ata
from repro.core.strassen import strassen_tn

__all__ = [
    "PowerSGDState",
    "init_state",
    "compress",
    "compress_sharded",
    "decompress",
    "error_feedback",
]


class PowerSGDState(NamedTuple):
    q: jax.Array      # (n, r) — persistent right factor (warm start)
    error: jax.Array  # (m, n) — error-feedback residual


def init_state(key, shape, rank: int) -> PowerSGDState:
    m, n = shape
    q = jax.random.normal(key, (n, rank), jnp.float32)
    return PowerSGDState(q=q, error=jnp.zeros((m, n), jnp.float32))


def _whiten(p: jax.Array, g, eps: float = 1e-6) -> jax.Array:
    """Whiten columns of p given its gram ``g = PᵀP`` (p ← p·L⁻ᵀ).

    The ridge scales with trace(g)/r so rank-deficient P (more compression
    rank than gradient rank) stays finite: null-space columns collapse to
    ~eps-scaled noise and contribute nothing to the reconstruction.

    ``g`` may be the packed :class:`~repro.core.SymmetricMatrix` straight
    off ``gram_rowshard(out='packed')`` — the Cholesky and the solve then
    run packed-native (``repro.solve``), so the gram is never densified on
    any device (the last consumer-side dense hole of the packed retrieval
    path).
    """
    from repro.core.symmetric import SymmetricMatrix

    r = p.shape[1]
    if isinstance(g, SymmetricMatrix):
        from repro.solve import cholesky, solve_triangular

        ridge = eps * (g.trace() / r + 1e-30) + 1e-30
        f = cholesky(g.add_scaled_identity(ridge))
        # p·L⁻ᵀ: solve X·Lᵀ = P  ⇔  L·Xᵀ = Pᵀ (forward, packed factor)
        return solve_triangular(f, p.T, transpose=False).T
    ridge = eps * (jnp.trace(g) / r + 1e-30) + 1e-30
    g = g + ridge * jnp.eye(r, dtype=g.dtype)
    l = jnp.linalg.cholesky(g)
    # solve p_new L^T = p  →  p_new = p · L^{-T}
    return jax.lax.linalg.triangular_solve(
        l, p, left_side=False, lower=True, transpose_a=True
    )


def _orthonormalize(p: jax.Array, eps: float = 1e-6) -> jax.Array:
    # (r, r) = pᵀp — the paper's op, planner-dispatched
    return _whiten(p, ata(p), eps)


def compress(
    g: jax.Array, state: PowerSGDState, *, n_base: Optional[int] = None
) -> Tuple[jax.Array, jax.Array, PowerSGDState]:
    """One PowerSGD round for a (m, n) gradient.

    Returns (p, q, new_state): all-reduce p and q across DP, then call
    :func:`decompress`. Error feedback is accumulated locally. The TN
    product is planner-dispatched unless ``n_base`` is pinned.
    """
    g = g.astype(jnp.float32) + state.error
    p = g @ state.q                                        # (m, r)
    p = _orthonormalize(p)
    q = strassen_tn(g, p, n_base=n_base)                   # GᵀP — TN product
    g_hat = p @ q.T
    return p, q, PowerSGDState(q=q, error=g - g_hat)


def compress_sharded(
    g_local: jax.Array,
    state: PowerSGDState,
    axis: str,
    *,
    n_base: Optional[int] = None,
    packed_block: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, PowerSGDState]:
    """One PowerSGD round for a **row-sharded** gradient — call inside
    ``shard_map`` with ``g_local``/``state.error`` holding this device's row
    block of the global ``(m, n)`` gradient (``state.q`` replicated).

    Exactly the row-shard of :func:`compress` (up to psum reassociation):
    ``P``'s rows stay sharded like ``G``'s, and the two collectives are

    * the orthonormalization gram ``PᵀP`` — ``gram_rowshard(out='packed')``,
      so the reduce moves the packed lower-triangular block stack, never a
      mirrored square (the paper's Prop. 4.2 saving on optimizer bytes);
    * the ``(n, r)`` factor ``Q = GᵀP`` — a psum over the row shards.

    Returns ``(p_local, q, state)`` with ``p_local`` and ``state.error``
    row-sharded and ``q`` replicated.
    """
    from repro.core.distributed import gram_rowshard

    g_local = g_local.astype(jnp.float32) + state.error
    p_local = g_local @ state.q                            # rows of P = G·Q
    gram = gram_rowshard(
        p_local, axis, n_base=n_base, out="packed", packed_block=packed_block
    )
    p_local = _whiten(p_local, gram)       # packed Cholesky — never densified
    q = jax.lax.psum(
        strassen_tn(g_local, p_local, n_base=n_base), axis  # GᵀP row-shard sum
    )
    g_hat_local = p_local @ q.T
    return p_local, q, PowerSGDState(q=q, error=g_local - g_hat_local)


def decompress(p: jax.Array, q: jax.Array) -> jax.Array:
    return p @ q.T


def error_feedback(state: PowerSGDState, g: jax.Array, g_hat: jax.Array) -> PowerSGDState:
    return PowerSGDState(q=state.q, error=g.astype(jnp.float32) - g_hat)
