"""PowerSGD-style low-rank gradient compression, built on the paper's ops.

For DP gradient reduction at scale, rank-r compression replaces the dense
all-reduce of a (m, n) gradient with all-reduces of (m, r) and (n, r)
factors (r ≪ min(m, n)). The hot linear algebra is the paper's:

  * ``Q ← GᵀP``  — a TN product → :func:`repro.core.strassen_tn`;
  * orthonormalization gram ``PᵀP`` — :func:`repro.core.ata` (+ Cholesky
    whitening, cheaper and TPU-friendlier than per-column Gram-Schmidt).

Error feedback keeps the compression unbiased over time: the residual
``G − P·Qᵀ`` is added back into the next step's gradient.

Usage: wrap the per-device (pre-all-reduce) gradients; the returned factors
are what the DP collective reduces. ``compress_tree``/``decompress_tree``
handle whole pytrees (2-D+ leaves compressed, small leaves passed through).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ata import ata
from repro.core.strassen import strassen_tn

__all__ = ["PowerSGDState", "init_state", "compress", "decompress", "error_feedback"]


class PowerSGDState(NamedTuple):
    q: jax.Array      # (n, r) — persistent right factor (warm start)
    error: jax.Array  # (m, n) — error-feedback residual


def init_state(key, shape, rank: int) -> PowerSGDState:
    m, n = shape
    q = jax.random.normal(key, (n, rank), jnp.float32)
    return PowerSGDState(q=q, error=jnp.zeros((m, n), jnp.float32))


def _orthonormalize(p: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Whiten columns of p via the ATA gram + Cholesky (p ← p·L⁻ᵀ).

    The ridge scales with trace(g)/r so rank-deficient P (more compression
    rank than gradient rank) stays finite: null-space columns collapse to
    ~eps-scaled noise and contribute nothing to the reconstruction.
    """
    g = ata(p)                # (r, r) = pᵀp — the paper's op, planner-dispatched
    r = p.shape[1]
    ridge = eps * (jnp.trace(g) / r + 1e-30) + 1e-30
    g = g + ridge * jnp.eye(r, dtype=g.dtype)
    l = jnp.linalg.cholesky(g)
    # solve p_new L^T = p  →  p_new = p · L^{-T}
    return jax.lax.linalg.triangular_solve(
        l, p, left_side=False, lower=True, transpose_a=True
    )


def compress(
    g: jax.Array, state: PowerSGDState, *, n_base: Optional[int] = None
) -> Tuple[jax.Array, jax.Array, PowerSGDState]:
    """One PowerSGD round for a (m, n) gradient.

    Returns (p, q, new_state): all-reduce p and q across DP, then call
    :func:`decompress`. Error feedback is accumulated locally. The TN
    product is planner-dispatched unless ``n_base`` is pinned.
    """
    g = g.astype(jnp.float32) + state.error
    p = g @ state.q                                        # (m, r)
    p = _orthonormalize(p)
    q = strassen_tn(g, p, n_base=n_base)                   # GᵀP — TN product
    g_hat = p @ q.T
    return p, q, PowerSGDState(q=q, error=g - g_hat)


def decompress(p: jax.Array, q: jax.Array) -> jax.Array:
    return p @ q.T


def error_feedback(state: PowerSGDState, g: jax.Array, g_hat: jax.Array) -> PowerSGDState:
    return PowerSGDState(q=state.q, error=g.astype(jnp.float32) - g_hat)
