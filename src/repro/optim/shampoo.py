"""Distributed Shampoo with ATA-powered gram statistics — the production
consumer of the paper's algorithm.

Shampoo's preconditioner statistics for a gradient block G are exactly the
paper's product:

    L += G·Gᵀ  =  ata(Gᵀ)        (b1 × b1)
    R += GᵀG   =  ata(G)         (b2 × b2)

computed **every step for every 2-D parameter block** — at production scale
these grams are a first-order cost, which is why the paper's 2/3-Strassen
saving is a real training-throughput lever. We compute them with
:func:`repro.core.ata_batched` over the blocks of the standard blocked-
Shampoo partitioning (pad → tile into ``block×block`` tiles): the batch of
parameter blocks is threaded through the recursion as a leading dimension,
so every base case is **one** batched syrk/gemm over all blocks rather than
a vmap of per-block launches.

With ``packed_grams=True`` (default) the L/R statistics are held in
**packed lower-triangular block form** (:class:`repro.core.SymmetricMatrix`)
end-to-end: the gram products come out of ``ata_batched(..., out="packed")``
mirror-free, the decayed accumulation runs on packed blocks, and the dense
square is materialized only inside the (every ``update_every`` steps)
inverse-root refresh. This roughly halves the resident memory of the L/R
optimizer state (exact ratio ``(k+1)/2k`` for ``k`` packed blocks per side).

The packed form survives **sharding** too: under ZeRO-1 the stat stacks
shard their leading block-batch dim over the ``data`` mesh axis
(``train_step.state_specs`` maps the packed 4-D ``(nb, T, bn, bn)`` leaves
the same way as dense 3-D ones — block ownership, the optimizer-level
analogue of the paper's disjoint tasks), so whatever GSPMD moves when
re-laying-out optimizer state is packed-block payload, ≈ half the dense
bytes. For row-sharded gram accumulation under explicit ``shard_map``, use
``repro.core.distributed.gram_rowshard(..., out='packed')`` — the psum then
reduces the packed stack directly (see ``optim.powersgd.compress_sharded``
for the worked consumer).

Other pieces follow Anil et al.'s distributed Shampoo: coupled-Newton
inverse p-th roots (p = 4 for 2-D blocks) refreshed every
``update_every`` steps under ``lax.cond``, Adam grafting for step size,
first-moment momentum on the grafted preconditioned update, and Adam
fallback for 1-D/scalar/embedding parameters.

``precond_p=2`` selects the **whitening** preconditioner (exponent −1/2 on
each gram stat): instead of Newton-iterated inverse roots, the refresh
factors the decayed stats — **packed Cholesky directly on the
SymmetricMatrix stacks** (``repro.solve.cholesky``; the stats are never
densified, closing the last dense ``O(n²)`` hole of the packed-grams
path) — and the update applies the factors as two packed triangular
solves, ``C_L⁻¹·G·C_R⁻ᵀ``. The optimizer state then holds packed
*factors*, so preconditioner memory halves along with the stats. With
``packed_grams=False`` the identical math runs densely
(``jnp.linalg.cholesky`` + ``triangular_solve``) — the two paths agree
within fp tolerance (tested), which is the packed path's correctness
anchor. Adam grafting transplants the step size either way, so the
whitened direction composes with the rest of the optimizer unchanged.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.ata import ata_batched
from repro.core.symmetric import SymmetricMatrix
from repro.optim.adamw import Optimizer
from repro.solve.cholesky import CholeskyFactor, cholesky as packed_cholesky
from repro.solve.triangular import solve_triangular

__all__ = ["shampoo", "inverse_pth_root"]

_SKIP_SUBSTRINGS = ("embed", "lm_head")  # Adam fallback for huge vocab tables


# ---------------------------------------------------------------------------
# inverse p-th root (coupled Newton, f32)
# ---------------------------------------------------------------------------


def _max_ev(a: jax.Array, iters: int = 16) -> jax.Array:
    """Power-iteration estimate of the largest eigenvalue (PSD input)."""
    n = a.shape[-1]
    v = jnp.full((n,), n ** -0.5, jnp.float32)

    def body(_, v):
        w = a @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.maximum(v @ (a @ v), 1e-30)


def inverse_pth_root(
    a: jax.Array, p: int = 4, iters: int = 25, ridge: float = 1e-6
) -> jax.Array:
    """``(A + εI)^{-1/p}`` for PSD A via the coupled Newton iteration.

    M₀ = A·z (eigs in (0,1]), X₀ = I;
    M₁ = ((p+1)I − M)/p;  X ← X·M₁;  M ← M₁ᵖ·M — X → (A·z)^{-1/p}.
    """
    n = a.shape[-1]
    eye = jnp.eye(n, dtype=jnp.float32)
    a = a.astype(jnp.float32)
    a = a + ridge * (jnp.trace(a) / n + 1e-30) * eye
    z = 1.0 / _max_ev(a)
    m0 = a * z
    alpha = -1.0 / p

    def body(_, carry):
        m, x = carry
        m1 = (1.0 - alpha) * eye + alpha * m      # = ((p+1)I − M)/p
        x = x @ m1
        m1p = m1
        for _ in range(p.bit_length() - 1):        # p = 4 → square twice
            m1p = m1p @ m1p
        if (1 << (p.bit_length() - 1)) != p:       # non-power-of-two p
            m1p = jnp.linalg.matrix_power(m1, p)
        m = m1p @ m
        return m, x

    _, x = jax.lax.fori_loop(0, iters, body, (m0, eye))
    return x * z ** (-alpha)                        # (A z)^{-1/p} · z^{1/p}


# ---------------------------------------------------------------------------
# blocked partitioning
# ---------------------------------------------------------------------------


class _Part(NamedTuple):
    d1: int
    d2: int
    b1: int
    b2: int
    n1: int
    n2: int


def _plan(shape, block: int) -> _Part:
    d1 = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
    d2 = shape[-1] if len(shape) > 1 else 1
    b1 = min(block, -(-d1 // 8) * 8)
    b2 = min(block, -(-d2 // 8) * 8)
    n1 = -(-d1 // b1)
    n2 = -(-d2 // b2)
    return _Part(d1, d2, b1, b2, n1, n2)


def _to_blocks(g: jax.Array, pt: _Part) -> jax.Array:
    g = g.reshape(pt.d1, pt.d2).astype(jnp.float32)
    pad1 = pt.n1 * pt.b1 - pt.d1
    pad2 = pt.n2 * pt.b2 - pt.d2
    if pad1 or pad2:
        g = jnp.pad(g, ((0, pad1), (0, pad2)))
    g = g.reshape(pt.n1, pt.b1, pt.n2, pt.b2).transpose(0, 2, 1, 3)
    return g.reshape(pt.n1 * pt.n2, pt.b1, pt.b2)


def _from_blocks(blocks: jax.Array, pt: _Part, shape) -> jax.Array:
    g = blocks.reshape(pt.n1, pt.n2, pt.b1, pt.b2).transpose(0, 2, 1, 3)
    g = g.reshape(pt.n1 * pt.b1, pt.n2 * pt.b2)[: pt.d1, : pt.d2]
    return g.reshape(shape)


def _use_shampoo(path: str, shape) -> bool:
    if any(s in path for s in _SKIP_SUBSTRINGS):
        return False
    return len(shape) >= 2 and min(shape[-1], math.prod(shape[:-1])) >= 8


# ---------------------------------------------------------------------------
# the optimizer
# ---------------------------------------------------------------------------


def shampoo(
    lr_schedule: Callable,
    block: int = 1024,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    update_every: int = 10,
    stat_decay: float = 0.95,
    n_base: Optional[int] = None,
    variant: Optional[str] = None,
    newton_iters: int = 25,
    packed_grams: bool = True,
    gram_block: Optional[int] = None,
    precond_p: int = 4,
    precond_ridge: float = 1e-6,
) -> Optimizer:
    """ATA-powered blocked Shampoo with Adam grafting.

    ``packed_grams`` keeps the L/R gram statistics in packed symmetric form
    (about half the memory; with ``precond_p=4`` they are densified only
    inside the preconditioner refresh). ``gram_block`` is the packed
    storage block size.

    ``precond_p`` selects the preconditioner exponent: 4 (Anil et al.'s
    inverse 4th roots via coupled Newton) or 2 — the whitening path, where
    the refresh is a **packed Cholesky** of each stat
    (``repro.solve.cholesky`` — no densify) and the update applies the
    factor by two triangular solves. ``precond_ridge`` is the p=2 refresh's
    relative ridge (scaled by ``trace/n``, like ``inverse_pth_root``'s).

    ``n_base``/``variant``/``gram_block`` default to None: the gram
    dispatches are then planned per block shape through ``repro.tune.plan``
    inside ``ata_batched`` (a pinned value bypasses the planner). Note the
    reproducibility trade-off: a *measured* plan in the persistent tune
    cache changes the gram recursion depth and hence float rounding — runs
    on machines with different cache states can diverge bitwise (never
    beyond normal fp reassociation). Pin ``n_base`` (e.g. via
    ``OptimizerConfig.shampoo_n_base``) for bitwise-reproducible training.
    """
    if precond_p not in (2, 4):
        raise ValueError(f"precond_p must be 2 or 4, got {precond_p}")
    if gram_block is None:
        from repro.tune.defaults import DEFAULT_PACKED_BLOCK

        gram_block = DEFAULT_PACKED_BLOCK

    gram_b = functools.partial(ata_batched, n_base=n_base, variant=variant)

    def _gram_stats(gb):
        """L/R gram products for all blocks of one parameter — one trace,
        one launch per base tile over the whole block batch (no vmap)."""
        out = "packed" if packed_grams else "dense"
        l_new = gram_b(jnp.swapaxes(gb, -1, -2), out=out, packed_block=gram_block)
        r_new = gram_b(gb, out=out, packed_block=gram_block)
        return l_new, r_new

    def _zeros_stat(n, nb):
        if packed_grams:
            return SymmetricMatrix.zeros(n, gram_block, batch=(nb,))
        return jnp.zeros((nb, n, n), jnp.float32)

    def _dense(stat):
        return stat.to_dense() if isinstance(stat, SymmetricMatrix) else stat

    # --- p=2 whitening path: packed Cholesky factors, never densified ---

    def _chol_refresh(stat, d):
        """Cholesky factor of the (relative-)ridged stat — packed in,
        packed out (the dense branch runs the identical math densely)."""
        if isinstance(stat, SymmetricMatrix):
            tr = stat.trace()                                   # (nb,)
            ridge = precond_ridge * (tr / d + 1e-30) + 1e-30
            return packed_cholesky(
                stat.add_scaled_identity(ridge[:, None, None, None])
            )
        tr = jnp.trace(stat, axis1=-2, axis2=-1)
        ridge = precond_ridge * (tr / d + 1e-30) + 1e-30
        eye = jnp.eye(d, dtype=jnp.float32)
        return jnp.linalg.cholesky(stat + ridge[:, None, None] * eye)

    def _id_factor(d, nb):
        """Well-posed init/keep value for a p=2 preconditioner slot."""
        if packed_grams:
            return CholeskyFactor.identity(d, gram_block, batch=(nb,))
        return jnp.stack([jnp.eye(d, dtype=jnp.float32)] * nb)

    def _whiten_apply(cl, gb, cr):
        """``C_L⁻¹ · G · C_R⁻ᵀ`` — packed triangular solves (or the dense
        ``lax.linalg.triangular_solve`` twin) on the block batch."""
        if isinstance(cl, CholeskyFactor):
            y = solve_triangular(cl, gb, transpose=False)
            zt = solve_triangular(cr, jnp.swapaxes(y, -1, -2), transpose=False)
            return jnp.swapaxes(zt, -1, -2)
        y = jax.lax.linalg.triangular_solve(
            cl, gb, left_side=True, lower=True
        )
        return jax.lax.linalg.triangular_solve(
            cr, y, left_side=False, lower=True, transpose_a=True
        )

    def _paths(params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        paths = [jax.tree_util.keystr(k) for k, _ in flat]
        leaves = [v for _, v in flat]
        return paths, leaves, treedef

    def init(params):
        paths, leaves, treedef = _paths(params)
        stats = []
        for path, p in zip(paths, leaves):
            if _use_shampoo(path, p.shape):
                pt = _plan(p.shape, block)
                nb = pt.n1 * pt.n2
                if precond_p == 2:
                    pl0, pr0 = _id_factor(pt.b1, nb), _id_factor(pt.b2, nb)
                else:
                    pl0 = jnp.stack([jnp.eye(pt.b1, dtype=jnp.float32)] * nb)
                    pr0 = jnp.stack([jnp.eye(pt.b2, dtype=jnp.float32)] * nb)
                stats.append(
                    {
                        "l": _zeros_stat(pt.b1, nb),
                        "r": _zeros_stat(pt.b2, nb),
                        "pl": pl0,
                        "pr": pr0,
                        "mom": jnp.zeros_like(p, dtype=jnp.float32),
                    }
                )
            else:
                stats.append(None)
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "shampoo": jax.tree_util.tree_unflatten(
                treedef, [s if s is not None else 0 for s in stats]
            ),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_schedule(step)
        bc1 = 1.0 - beta1 ** step.astype(jnp.float32)
        bc2 = 1.0 - beta2 ** step.astype(jnp.float32)
        refresh = (step % update_every) == 0

        g_paths, g_leaves, treedef = _paths(grads)
        p_leaves = jax.tree.leaves(params)
        m_leaves = jax.tree.leaves(state["m"])
        v_leaves = jax.tree.leaves(state["v"])
        s_leaves = treedef.flatten_up_to(state["shampoo"])

        new_updates, new_m, new_v, new_s = [], [], [], []
        for path, g, p, m, v, s in zip(
            g_paths, g_leaves, p_leaves, m_leaves, v_leaves, s_leaves
        ):
            g = g.astype(jnp.float32)
            m = beta1 * m + (1 - beta1) * g
            v = beta2 * v + (1 - beta2) * g * g
            adam_dir = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new_m.append(m)
            new_v.append(v)

            if not isinstance(s, dict):
                u = -lr * (adam_dir + weight_decay * p.astype(jnp.float32))
                new_updates.append(u)
                new_s.append(s)
                continue

            pt = _plan(p.shape, block)
            gb = _to_blocks(g, pt)                              # (nb, b1, b2)

            # --- the paper's product: gram statistics via batched ATA ---
            # (packed mode: mirror-free SymmetricMatrix accumulation)
            l_new, r_new = _gram_stats(gb)
            l = stat_decay * s["l"] + (1 - stat_decay) * l_new
            r = stat_decay * s["r"] + (1 - stat_decay) * r_new

            if precond_p == 2:
                # whitening: packed Cholesky of the stats — no densify
                def _refresh(l=l, r=r):
                    return _chol_refresh(l, pt.b1), _chol_refresh(r, pt.b2)

            else:

                def _refresh(l=l, r=r):
                    # densify only here — once per `update_every` steps
                    pl = jax.vmap(
                        lambda x: inverse_pth_root(x, 4, newton_iters)
                    )(_dense(l))
                    pr = jax.vmap(
                        lambda x: inverse_pth_root(x, 4, newton_iters)
                    )(_dense(r))
                    return pl, pr

            def _keep(l=l, r=r):
                return s["pl"], s["pr"]

            pl, pr = jax.lax.cond(refresh, _refresh, _keep)

            if precond_p == 2:
                pg = _whiten_apply(pl, gb, pr)
            else:
                pg = jax.vmap(lambda a, x, b: a @ x @ b)(pl, gb, pr)
            # Adam grafting: per-block norm transplant
            ab = _to_blocks(adam_dir, pt)
            a_norm = jnp.sqrt(jnp.sum(ab * ab, axis=(1, 2)) + 1e-30)
            s_norm = jnp.sqrt(jnp.sum(pg * pg, axis=(1, 2)) + 1e-30)
            pg = pg * (a_norm / s_norm)[:, None, None]
            pg = _from_blocks(pg, pt, p.shape)

            mom = beta1 * s["mom"] + pg
            u = -lr * (mom + weight_decay * p.astype(jnp.float32))
            new_updates.append(u)
            new_s.append({"l": l, "r": r, "pl": pl, "pr": pr, "mom": mom})

        unflatten = functools.partial(jax.tree_util.tree_unflatten, treedef)
        return unflatten(new_updates), {
            "m": unflatten(new_m),
            "v": unflatten(new_v),
            "shampoo": unflatten(new_s),
            "step": step,
        }

    return Optimizer(init, update)
