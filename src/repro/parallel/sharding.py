"""Sharding rules: logical axes → mesh axes, with divisibility fallbacks.

The production meshes are ``(data=16, model=16)`` and
``(pod=2, data=16, model=16)``. Assigned-pool dimensions are *not* all
divisible by 16 (hymba has 25 heads / 5 kv heads, qwen2-moe has 60 experts,
mamba2's vocab is 50280), so rules degrade gracefully:

* ``pick(dim, candidates)`` returns the first mesh-axis tuple whose size
  divides ``dim`` (None = replicate). Head-sharding falls back to
  row-parallel (contract-dim) sharding, which is always legal because every
  ``d_model`` in the pool divides 16.
* vocab/embedding tables are padded up to a multiple of
  ``model_axis · 128`` (``pad_vocab``) — standard production practice.
* experts are padded up to the model-axis size for EP (qwen2-moe 60 → 64,
  router-masked dummies).

The rules produce ``PartitionSpec`` trees for params, optimizer states,
activations and KV caches; GSPMD propagates the rest.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = [
    "MeshAxes",
    "pad_vocab",
    "pad_experts",
    "pick",
    "param_specs",
    "batch_spec",
    "activation_spec",
    "cache_specs",
    "batch_input_specs",
    "data_axes",
]

AxisT = Union[None, str, Tuple[str, ...]]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The pure-DP axes: ('pod', 'data') when multi-pod, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axes_size(mesh: Mesh, axes: AxisT) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def pick(mesh: Mesh, dim: int, candidates: Sequence[AxisT]) -> AxisT:
    """First candidate axis (tuple) whose total size divides ``dim``."""
    for cand in candidates:
        if dim % _axes_size(mesh, cand) == 0:
            return cand
    return None


def pad_vocab(vocab: int, mesh: Mesh) -> int:
    """Pad vocab to a multiple of model_axis·128 (MXU lane × shard count)."""
    mult = mesh.shape.get("model", 1) * 128
    return -(-vocab // mult) * mult


def pad_experts(num_experts: int, mesh: Mesh) -> int:
    """Pad routed-expert count up to a multiple of the model axis for EP."""
    m = mesh.shape.get("model", 1)
    return -(-num_experts // m) * m


def batch_spec(mesh: Mesh, shape: ShapeConfig) -> P:
    """Token batch (B, S) sharding: B over DP axes; for global_batch too
    small to shard (long_500k B=1), shard the sequence instead."""
    dp = data_axes(mesh)
    if shape.global_batch % _axes_size(mesh, dp) == 0:
        return P(dp, None)
    # long-context single-sequence: sequence sharding over the DP axes
    if shape.seq_len % _axes_size(mesh, dp) == 0:
        return P(None, dp)
    return P(None, None)


def activation_spec(mesh: Mesh, shape: ShapeConfig) -> P:
    """(B, S, D) activations."""
    bs = batch_spec(mesh, shape)
    return P(bs[0], bs[1], None)


def _div(mesh: Mesh, dim: int, axes: AxisT) -> bool:
    return axes is not None and dim % _axes_size(mesh, axes) == 0 and dim >= _axes_size(mesh, axes)


def cache_specs(mesh: Mesh, cfg: ModelConfig, cache_abs) -> dict:
    """PartitionSpec tree for a decode cache (``init_cache`` structure).

    * ``k``/``v`` leaves (…, S_cache, KV, HD): batch → DP axes, cache
      sequence → ``model`` (sequence-parallel decode — uniform across archs
      regardless of head count, see DESIGN.md §6).
    * ``h`` SSD states (…, B, H, P, N): batch → DP, then H (or P) → model.
    * ``conv`` states (…, B, K-1, C): batch → DP, channels → model.
    """
    dp = data_axes(mesh)
    m = "model" if "model" in mesh.shape else None

    def leaf_spec(path, ab):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        shape = ab.shape
        nd = len(shape)
        parts = [None] * nd
        if name in ("k", "v"):
            b_i, s_i = nd - 4, nd - 3
            if _div(mesh, shape[b_i], dp):
                parts[b_i] = dp
            if m and _div(mesh, shape[s_i], m):
                parts[s_i] = m
        elif name == "h":
            b_i = nd - 4
            if _div(mesh, shape[b_i], dp):
                parts[b_i] = dp
            for i in (nd - 3, nd - 2):
                if m and _div(mesh, shape[i], m):
                    parts[i] = m
                    break
        elif name == "conv":
            b_i = nd - 3
            if _div(mesh, shape[b_i], dp):
                parts[b_i] = dp
            if m and _div(mesh, shape[nd - 1], m):
                parts[nd - 1] = m
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_abs)


def batch_input_specs(mesh: Mesh, batch_abs) -> dict:
    """PartitionSpec tree for model inputs (tokens/labels/image_embeds/pos):
    batch dim → DP axes when divisible, else the sequence dim (long-context
    single-sequence cells)."""
    dp = data_axes(mesh)

    def leaf_spec(path, ab):
        shape = ab.shape
        parts = [None] * len(shape)
        if len(shape) >= 1 and _div(mesh, shape[0], dp):
            parts[0] = dp
        elif len(shape) >= 2 and _div(mesh, shape[1], dp):
            parts[1] = dp  # seq sharding for batch-1 long context
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_abs)


def param_specs(mesh: Mesh, cfg: ModelConfig) -> dict:
    """PartitionSpec tree matching the param pytree of models.init."""
    m = "model" if "model" in mesh.shape else None
    d = cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    # attention projections: prefer head-sharding (column-parallel), fall
    # back to contract-dim (row-parallel) sharding on d_model.
    q_spec = (
        P(None, m, None) if m and h % mesh.shape["model"] == 0
        else P(m, None, None)
    )
    kv_spec = (
        P(None, m, None) if m and kv % mesh.shape["model"] == 0
        else P(m, None, None)
    )
    o_spec = (
        P(m, None, None) if m and h % mesh.shape["model"] == 0
        else P(None, None, m)
    )

    specs: dict = {
        "embed": P(m, None),            # (vocab_padded, d)
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, m)   # (d, vocab_padded)

    layer: dict = {}
    if cfg.family != "ssm":
        attn = {
            "wq": q_spec,
            "wk": kv_spec,
            "wv": kv_spec,
            "wo": o_spec,
            "norm": P(None),
        }
        if cfg.qkv_bias:
            attn["bq"] = P(m, None) if q_spec == P(None, m, None) else P(None, None)
            attn["bk"] = P(m, None) if kv_spec == P(None, m, None) else P(None, None)
            attn["bv"] = attn["bk"]
        layer["attn"] = attn

    if cfg.ssm is not None:
        layer["ssm"] = {
            "x_proj": P(None, m),       # (d, d_inner)
            "z_proj": P(None, m),
            "bc_proj": P(None, None),   # (d, 2·d_state) — small, replicated
            "dt_proj": P(None, None),   # (d, n_heads_ssm)
            "conv": P(m, None),         # (d_inner, d_conv) depthwise
            "a_log": P(None),           # (n_heads_ssm,)
            "d_skip": P(None),
            "gnorm": P(m),              # (d_inner,)
            "out_proj": P(m, None),     # (d_inner, d)
            "norm": P(None),
        }

    if cfg.moe is not None:
        ep_ok = cfg.moe.sharding == "ep"
        e_axis = m if ep_ok else None
        f_axis = None if ep_ok else m
        layer["moe"] = {
            "router": P(None, None),                  # (d, E_padded)
            "wg": P(e_axis, None, f_axis),            # (E, d, ff)
            "wu": P(e_axis, None, f_axis),
            "wd": P(e_axis, f_axis, None),            # (E, ff, d)
            "norm": P(None),
        }
        if cfg.moe.num_shared:
            layer["shared_mlp"] = {
                "wg": P(None, m),                     # shared experts fused: TP
                "wu": P(None, m),
                "wd": P(m, None),
            }
    elif cfg.d_ff:
        layer["mlp"] = {
            "wg": P(None, m),
            "wu": P(None, m),
            "wd": P(m, None),
            "norm": P(None),
        }

    if cfg.scan_layers:
        # scanned params carry a leading L dim
        specs["layers"] = jax.tree.map(
            lambda s: P(None, *s), layer, is_leaf=lambda x: isinstance(x, P)
        )
    else:
        specs["layers"] = [layer for _ in range(cfg.num_layers)]
    return specs


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
